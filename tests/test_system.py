"""End-to-end system tests: the real training launcher, specs consistency,
and the mesh helpers."""
import os
import subprocess
import sys

import jax
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_launcher(*extra, timeout=540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    cmd = [sys.executable, "-m", "repro.launch.train", "--steps", "6",
           "--batch", "4", "--seq", "64", "--data-axis", "1"] + list(extra)
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)


def parse_losses(stdout):
    return [float(l.split("loss")[1].split()[0])
            for l in stdout.splitlines() if l.startswith("step")]


def test_train_launcher_runs_and_learns():
    proc = run_launcher("--arch", "qwen1.5-0.5b", "--steps", "10")
    assert proc.returncode == 0, proc.stderr[-3000:]
    losses = parse_losses(proc.stdout)
    assert len(losses) == 10
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]          # the synthetic corpus is learnable


def test_train_launcher_psum_schedule():
    proc = run_launcher("--arch", "granite-3-2b", "--schedule", "tolfl_psum")
    assert proc.returncode == 0, proc.stderr[-3000:]
    losses = parse_losses(proc.stdout)
    assert len(losses) == 6 and all(np.isfinite(losses))


def test_train_launcher_with_failure_injection():
    proc = run_launcher("--arch", "qwen1.5-0.5b", "--fail-epoch", "3",
                        "--fail-kind", "server")
    assert proc.returncode == 0, proc.stderr[-3000:]
    losses = parse_losses(proc.stdout)
    assert len(losses) == 6 and all(np.isfinite(losses))


def test_train_launcher_checkpointing(tmp_path):
    proc = run_launcher("--arch", "qwen1.5-0.5b", "--steps", "10",
                        "--ckpt-dir", str(tmp_path))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert any(f.startswith("ckpt_") for f in os.listdir(tmp_path))


def test_serve_launcher_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch",
         "qwen1.5-0.5b", "--batch", "2", "--prompt", "16", "--tokens", "4"],
        env=env, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "decode:" in proc.stdout and "prefill:" in proc.stdout


# ---------------------------------------------------------------------------
# launch specs consistency
# ---------------------------------------------------------------------------
def test_state_specs_match_init_state():
    """The dry-run state ShapeDtypeStructs must exactly mirror what
    init_state would materialise."""
    from repro.configs import ARCHS, OptimizerConfig
    from repro.core import distributed as D
    from repro.launch import specs as SP
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import logical as L

    cfg = ARCHS["qwen1.5-0.5b"].reduced()
    ocfg = OptimizerConfig()
    mesh = make_host_mesh(data=1, model=1)
    rules = L.rules_for("replicated_data")
    spec_tree = SP.state_specs(cfg, ocfg, mesh, rules)
    shape_tree = jax.eval_shape(lambda k: D.init_state(k, cfg, ocfg),
                                jax.random.PRNGKey(0))
    flat_spec = jax.tree.leaves(spec_tree)
    flat_shape = jax.tree.leaves(shape_tree)
    assert len(flat_spec) == len(flat_shape)
    for a, b in zip(flat_spec, flat_shape):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_input_specs_cover_all_arch_shape_combos():
    """Every (arch x shape) pair must produce lowering-ready specs."""
    from repro.configs import ARCHS, INPUT_SHAPES
    from repro.launch import specs as SP
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import logical as L

    mesh = make_host_mesh(data=1, model=1)
    rules = L.rules_for("replicated_data")
    for arch, cfg in ARCHS.items():
        for name, shape in INPUT_SHAPES.items():
            if shape.mode == "train":
                b = SP.train_batch_specs(cfg, shape, mesh, rules)
                assert "tokens" in b and "labels" in b
                if cfg.frontend.kind == "vision":
                    assert "prefix" in b
                if cfg.is_encdec:
                    assert "frames" in b
            elif shape.mode == "prefill":
                b = SP.prefill_specs(cfg, shape, mesh, rules)
                assert b["tokens"].shape[0] == shape.global_batch
            else:
                d = SP.decode_specs(cfg, shape, mesh, rules,
                                    long_context=(name == "long_500k"))
                assert d["tokens"].shape == (shape.global_batch, 1)
                assert "cache" in d


def test_production_mesh_contract():
    """make_production_mesh builds the brief's meshes.  On this 1-CPU host
    we can't construct 256 devices, so assert the function contract (the
    dry-run constructs them for real under the 512-device flag)."""
    from repro.launch import mesh as M
    import inspect
    src = inspect.getsource(M.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src.replace("'", '"')


def test_long500k_decode_cache_subquadratic():
    """long_500k decode must NOT materialise a 500k-token KV cache for
    archs with a sub-quadratic path (the brief's requirement)."""
    from repro.configs import ARCHS
    from repro.serving.decode import cache_shape

    # hybrid: local-attn layers cap at the 2048 window; recurrent O(1)
    cs = cache_shape(ARCHS["recurrentgemma-9b"], 1, 524288,
                     long_context=True)
    for leaf in jax.tree.leaves(cs):
        assert 524288 not in leaf.shape

    # ssm: O(1) state only
    cs = cache_shape(ARCHS["rwkv6-7b"], 1, 524288, long_context=True)
    for leaf in jax.tree.leaves(cs):
        assert 524288 not in leaf.shape

    # dense long-context variant: ring capped at long_context_window
    cs = cache_shape(ARCHS["qwen3-8b"], 1, 524288, long_context=True)
    for leaf in jax.tree.leaves(cs):
        assert 524288 not in leaf.shape
