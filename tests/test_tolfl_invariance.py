"""The paper's headline mathematical property, end-to-end: Tol-FL model
updates are INDEPENDENT of the cluster count k (Section III — "model
updates from a round of training are independent of k and result in
identical outputs").

We run the full simulator with k in {1 (FL), 2, 5, 10 (SBT)} on identical
data/seeds and assert bit-near-identical loss trajectories, plus
streaming-vs-direct combine equality.
"""
import numpy as np
import pytest

from repro.core.failure import NO_FAILURE
from repro.core.simulate import SimConfig, run_simulation


ROUNDS = 12


def run(ae_cfg, padded, split, scheme, k, combine="streaming", seed=0):
    dx, counts = padded
    # lr 5e-4: stable descent over the short window (1e-3 oscillates on
    # this draw — the loss dips then recrosses its start by round 12)
    cfg = SimConfig(scheme=scheme, num_devices=10, num_clusters=k,
                    rounds=ROUNDS, lr=5e-4, dropout=False, seed=seed,
                    combine=combine)
    return run_simulation(ae_cfg, dx, counts, split.test_x, split.test_y,
                          cfg, NO_FAILURE)


@pytest.fixture(scope="module")
def curves(tiny_ae_cfg, tiny_padded, tiny_split):
    out = {}
    for scheme, k in (("fl", 1), ("tolfl", 2), ("tolfl", 5), ("sbt", 10)):
        out[(scheme, k)] = run(tiny_ae_cfg, tiny_padded, tiny_split,
                               scheme, k)
    return out


def test_k_invariance_loss_curves(curves):
    base = curves[("fl", 1)].loss_curve
    for key, res in curves.items():
        np.testing.assert_allclose(
            res.loss_curve, base, rtol=1e-4, atol=1e-5,
            err_msg=f"k-invariance violated for {key}")


def test_k_invariance_auroc(curves):
    base = curves[("fl", 1)].final_auroc
    for key, res in curves.items():
        np.testing.assert_allclose(res.final_auroc, base, atol=1e-3,
                                   err_msg=str(key))


def test_streaming_equals_direct_combine(tiny_ae_cfg, tiny_padded,
                                         tiny_split):
    a = run(tiny_ae_cfg, tiny_padded, tiny_split, "tolfl", 5, "streaming")
    b = run(tiny_ae_cfg, tiny_padded, tiny_split, "tolfl", 5, "direct")
    np.testing.assert_allclose(a.loss_curve, b.loss_curve, rtol=1e-4,
                               atol=1e-5)


def test_loss_decreases(curves):
    for key, res in curves.items():
        assert res.loss_curve[-1] < res.loss_curve[0], key
