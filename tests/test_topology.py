"""Topology bookkeeping invariants (cluster structure, ring permutations)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.topology import Topology, special_cases


@st.composite
def topo_strategy(draw):
    k = draw(st.integers(1, 16))
    members = draw(st.integers(1, 16))
    return Topology(k * members, k)


@settings(max_examples=50, deadline=None)
@given(topo=topo_strategy())
def test_clusters_partition_devices(topo):
    seen = [d for c in topo.clusters for d in c]
    assert sorted(seen) == list(range(topo.num_devices))
    assert len(topo.clusters) == topo.num_clusters
    sizes = {len(c) for c in topo.clusters}
    assert sizes == {topo.members_per_cluster}


@settings(max_examples=50, deadline=None)
@given(topo=topo_strategy())
def test_heads_are_first_members(topo):
    assert topo.heads == [c[0] for c in topo.clusters]
    for h in topo.heads:
        assert topo.is_head(h)
    non_heads = set(range(topo.num_devices)) - set(topo.heads)
    for d in non_heads:
        assert not topo.is_head(d)


@settings(max_examples=50, deadline=None)
@given(topo=topo_strategy())
def test_cluster_of_consistent(topo):
    for ci, devs in enumerate(topo.clusters):
        for d in devs:
            assert topo.cluster_of(d) == ci
    ids = topo.device_cluster_array()
    assert ids.shape == (topo.num_devices,)
    np.testing.assert_array_equal(
        ids, [topo.cluster_of(d) for d in range(topo.num_devices)])


@settings(max_examples=50, deadline=None)
@given(topo=topo_strategy())
def test_ring_perms_chain_heads(topo):
    perms = topo.ring_perms()
    assert len(perms) == topo.num_clusters - 1
    h = topo.heads
    for i, p in enumerate(perms):
        assert p == [(h[i], h[i + 1])]


@settings(max_examples=50, deadline=None)
@given(topo=topo_strategy())
def test_head_mask(topo):
    m = topo.head_mask()
    assert m.sum() == topo.num_clusters
    np.testing.assert_array_equal(np.where(m)[0], topo.heads)


def test_special_cases():
    sc = special_cases(12)
    assert sc["fl"].num_clusters == 1           # FL = Tol-FL(k=1)
    assert sc["sbt"].num_clusters == 12         # SBT = Tol-FL(k=N)
    assert sc["fl"].heads == [0]
    assert sc["sbt"].heads == list(range(12))


def test_uneven_clusters_rejected():
    with pytest.raises(AssertionError):
        Topology(10, 3)
    with pytest.raises(AssertionError):
        Topology(4, 5)
